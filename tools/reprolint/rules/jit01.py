"""JIT01 — host syncs and impure calls inside jit-traced code.

The generic scan core (core/engine.py) traces one step function per
(substrate, protocol) pair and reuses it for every driver; a host
sync inside that trace either fails at trace time
(``ConcretizationTypeError`` from ``int()``/``float()`` on a tracer),
silently materializes on the host (``np.asarray``), or defeats async
dispatch (``block_until_ready``, ``print``).  The node face of a
Substrate is host-side by design and uses numpy freely — so this rule
is scoped to the *jit roots*:

* functions decorated with ``jax.jit`` (or ``partial(jax.jit, ...)``);
* function defs referenced by a ``jax.jit`` / ``lax.scan`` /
  ``lax.cond`` / ``lax.while_loop`` / ``lax.fori_loop`` call in the
  same file (the scan-core step builders);
* the scan-face methods of ``*Substrate`` classes (the set the engine
  traces; the node face — ``update_one``, ``upload_payload``,
  ``snapshot_buffers``, ... — is deliberately NOT here);
* any function nested inside one of the above.

Detection is syntactic and file-local (no cross-file call graph):
banned calls are flagged anywhere in a root's body; ``float()`` /
``int()`` only when their argument mentions a parameter of the root
(a traced name), so trace-time casts of static config stay legal.
"""
from __future__ import annotations

from typing import Iterable, List, Set

import ast

from ..engine import FileContext, Finding, dotted_name, names_in
from . import Rule

JIT_NAMES = frozenset({"jax.jit", "jit"})
PARTIAL_NAMES = frozenset({"functools.partial", "partial"})
LAX_HOFS = frozenset({
    "lax.scan", "jax.lax.scan", "lax.cond", "jax.lax.cond",
    "lax.while_loop", "jax.lax.while_loop",
    "lax.fori_loop", "jax.lax.fori_loop", "lax.map", "jax.lax.map",
})

#: Methods the engine traces on every Substrate (the scan face,
#: DESIGN.md Sec. 8).  Keep in sync with core/substrate.py.
SCAN_FACE = frozenset({
    "predict", "predict_batch", "update", "round_stacked",
    "average_stacked", "adopt", "dist_to_ref", "dist_to_ref_each",
    "divergence", "sync_payload", "models_of", "with_models",
})

BANNED_CALLS = frozenset({
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
})
BANNED_METHODS = frozenset({"item", "block_until_ready", "tolist"})


def _is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit``, ``jit``, ``partial(jax.jit, ...)`` and
    ``jax.jit(...)`` / ``partial(...)`` call forms."""
    name = dotted_name(node)
    if name in JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        if fname in JIT_NAMES:
            return True
        if fname in PARTIAL_NAMES and node.args:
            return _is_jit_expr(node.args[0])
    return False


class Jit01(Rule):
    id = "JIT01"
    title = ("host sync / impure call inside a jit-traced function "
             "(scan core or Substrate scan face)")

    def applies_to(self, path: str) -> bool:
        return "repro/" in path

    # -- root discovery ------------------------------------------------------

    def _roots(self, ctx: FileContext) -> List[ast.AST]:
        roots: List[ast.AST] = []
        defs_by_name = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)
                if any(_is_jit_expr(d) for d in node.decorator_list):
                    roots.append(node)

        # names referenced by jit()/lax.scan()/... calls in this file
        referenced: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func)
            if fname in JIT_NAMES or fname in LAX_HOFS:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        referenced.add(arg.id)
            elif fname in PARTIAL_NAMES and node.args:
                if _is_jit_expr(node.args[0]):
                    for arg in node.args[1:]:
                        if isinstance(arg, ast.Name):
                            referenced.add(arg.id)
        for name in referenced:
            roots.extend(defs_by_name.get(name, []))

        # scan-face methods of Substrate classes
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            base_names = {dotted_name(b) or "" for b in node.bases}
            is_sub = (node.name.endswith("Substrate")
                      or any(b.endswith("Substrate") for b in base_names))
            if not is_sub:
                continue
            for item in node.body:
                if (isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and item.name in SCAN_FACE):
                    roots.append(item)

        return roots

    # -- body checks ---------------------------------------------------------

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        seen: Set[int] = set()
        for root in self._roots(ctx):
            if id(root) in seen:
                continue
            seen.add(id(root))
            params = {a.arg for a in (root.args.posonlyargs + root.args.args
                                      + root.args.kwonlyargs)} - {"self"}
            if root.args.vararg:
                params.add(root.args.vararg.arg)
            for node in ast.walk(root):
                if not isinstance(node, ast.Call):
                    continue
                fname = dotted_name(node.func)
                where = f"jit-traced `{root.name}`"
                if fname in BANNED_CALLS:
                    out.append(ctx.finding(
                        self.id, node,
                        f"`{fname}` inside {where} forces a host "
                        "materialization; stay in jnp (DESIGN.md Sec. 8)"))
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in BANNED_METHODS
                        and not node.args and not node.keywords):
                    out.append(ctx.finding(
                        self.id, node,
                        f"`.{node.func.attr}()` inside {where} is a "
                        "device->host sync; keep values traced "
                        "(DESIGN.md Sec. 8)"))
                elif fname == "print":
                    out.append(ctx.finding(
                        self.id, node,
                        f"`print` inside {where} runs at trace time only "
                        "(or syncs via callbacks); use "
                        "jax.debug.print if needed (DESIGN.md Sec. 8)"))
                elif fname in ("float", "int", "bool") and node.args:
                    if names_in(node.args[0]) & params:
                        out.append(ctx.finding(
                            self.id, node,
                            f"`{fname}()` on a traced argument of {where} "
                            "raises ConcretizationTypeError under jit; "
                            "use .astype / lax ops (DESIGN.md Sec. 8)"))
        return out
