"""Diff two benchmark-report directories; nonzero exit on regression.

  python tools/bench_compare.py BASELINE_DIR CANDIDATE_DIR \
      [--threshold 1.5] [--threshold-for 'engine/*=2.0' ...] \
      [--min-us 100]

Both directories hold ``BENCH_<suite>.json`` files written by
``python -m benchmarks.run --json-dir DIR`` (schema:
``benchmarks/common.py``).  Three regression classes:

* timing — a row's candidate ``us_per_call`` exceeds baseline by more
  than the threshold ratio.  The default ratio applies everywhere;
  ``--threshold-for PATTERN=RATIO`` (fnmatch on the row name, first
  match wins, repeatable) overrides it per metric.  Rows whose
  baseline is below ``--min-us`` are too noisy to gate and are skipped.
* claims — a claim that was True in the baseline is False in the
  candidate (``serving_losses_identical=True`` -> ``=False``).
* coverage — a suite or row present in the baseline is missing from
  the candidate.
* bytes — a ``*bytes*``-named metric in a row's ``derived`` string
  differs from the baseline.  Byte ledgers are integer-exact and
  deterministic under seed (DESIGN.md Sec. 7), so unlike timings they
  are compared as exact ints at any magnitude — ``--allow-bytes-drift``
  downgrades this to a warning for cross-version comparisons where a
  numerics change legitimately moved sync decisions.

Self-diff of a directory against itself is a no-op and exits 0 — CI
runs exactly that as a sanity check of the comparator itself.
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

# run.py imports benchmarks.common via the package; this tool must work
# standalone (`python tools/bench_compare.py`), so resolve the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import load_report  # noqa: E402

import re

#: ``bytes=150336`` / ``hbm_gram_bytes=262144`` inside a row's derived
#: string — integer-valued byte metrics only; ``bytes_identical=True``
#: style claims don't match (no integer value), ratios don't either.
BYTES_METRIC_RE = re.compile(r"\b([\w]*bytes[\w]*)=(-?\d+)\b")


def byte_metrics(row: dict) -> Dict[str, int]:
    """name -> exact int value for every byte metric in ``derived``."""
    derived = row.get("derived") or ""
    return {name: int(val)
            for name, val in BYTES_METRIC_RE.findall(derived)}


def load_dir(path: str) -> Dict[str, dict]:
    """suite -> validated report for every BENCH_*.json under path."""
    reports = {}
    for fname in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        doc = load_report(fname)
        reports[doc["suite"]] = doc
    if not reports:
        raise ValueError(f"no BENCH_*.json files in {path!r}")
    return reports


def threshold_for(name: str, default: float,
                  overrides: Sequence[Tuple[str, float]]) -> float:
    for pattern, ratio in overrides:
        if fnmatch.fnmatch(name, pattern):
            return ratio
    return default


def compare(baseline: Dict[str, dict], candidate: Dict[str, dict],
            threshold: float = 1.5,
            overrides: Sequence[Tuple[str, float]] = (),
            min_us: float = 100.0,
            bytes_exact: bool = True) -> List[str]:
    """Regression messages; empty means the candidate passes."""
    regressions: List[str] = []
    for suite, base in sorted(baseline.items()):
        cand = candidate.get(suite)
        if cand is None:
            regressions.append(f"[coverage] suite {suite!r} missing "
                               "from candidate")
            continue
        cand_rows = {r["name"]: r for r in cand["rows"]}
        for row in base["rows"]:
            name = row["name"]
            other = cand_rows.get(name)
            if other is None:
                regressions.append(f"[coverage] row {name!r} missing "
                                   "from candidate")
                continue
            base_bytes = byte_metrics(row)
            cand_bytes = byte_metrics(other)
            for metric, want in sorted(base_bytes.items()):
                got = cand_bytes.get(metric)
                if got is not None and got != want:
                    msg = (f"[bytes] {name}/{metric}: {want} -> {got} "
                           "(byte ledgers are exact under seed)")
                    if bytes_exact:
                        regressions.append(msg)
                    else:
                        print(f"WARNING {msg}")
            if row["us_per_call"] < min_us:
                continue
            limit = threshold_for(name, threshold, overrides)
            ratio = other["us_per_call"] / row["us_per_call"]
            if ratio > limit:
                regressions.append(
                    f"[timing] {name}: {row['us_per_call']:.1f}us -> "
                    f"{other['us_per_call']:.1f}us "
                    f"({ratio:.2f}x > {limit:.2f}x)")
        for claim, held in sorted(base["claims"].items()):
            if held and candidate[suite]["claims"].get(claim) is False:
                regressions.append(f"[claim] {claim}: True -> False")
    return regressions


def _parse_override(spec: str) -> Tuple[str, float]:
    pattern, sep, ratio = spec.rpartition("=")
    if not sep:
        raise argparse.ArgumentTypeError(
            f"expected PATTERN=RATIO, got {spec!r}")
    return pattern, float(ratio)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="directory of baseline BENCH_*.json")
    ap.add_argument("candidate", help="directory of candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="default allowed us_per_call ratio (default 1.5)")
    ap.add_argument("--threshold-for", type=_parse_override, action="append",
                    default=[], metavar="PATTERN=RATIO",
                    help="per-metric override, fnmatch on row name; "
                    "first match wins (repeatable)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="skip timing gates on rows whose baseline is "
                    "below this (default 100)")
    ap.add_argument("--allow-bytes-drift", action="store_true",
                    help="report byte-metric changes as warnings instead "
                    "of regressions (for cross-version comparisons)")
    args = ap.parse_args(argv)

    baseline = load_dir(args.baseline)
    candidate = load_dir(args.candidate)
    regressions = compare(baseline, candidate, threshold=args.threshold,
                          overrides=args.threshold_for, min_us=args.min_us,
                          bytes_exact=not args.allow_bytes_drift)
    n_rows = sum(len(r["rows"]) for r in baseline.values())
    print(f"compared {len(baseline)} suites / {n_rows} rows: "
          f"{len(regressions)} regressions")
    for msg in regressions:
        print(msg)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
