#!/usr/bin/env python3
"""Quick substrate matrix check (run in CI).

Runs every substrate (SV / RFF / linear) x protocol kind
{periodic, dynamic} x backend {reference, pallas} through THREE
drivers — the device-resident scan engine (``core.engine.run``), the
asynchronous event-driven harness (``repro.runtime``), and the online
serving engine (``repro.serving.serve_stream``) — and asserts the
invariants every cell must satisfy:

- finite cumulative loss, at least one synchronization;
- byte ledger consistent with the sync count (for the fixed-payload
  substrates, total bytes == num_syncs * 2 m (p+1) B exactly);
- the engine and the zero-latency async run agree on the sync count
  for the fixed-payload substrates (their aggregation is exact);
- the serving replay's protocol view (syncs, bytes) equals the scan
  engine's for the same stream;
- the pallas backend's ledger (syncs, bytes, cumulative loss) is
  BIT-IDENTICAL to the reference backend's per driver — at these
  sizes every pallas substrate runs its engage-aware reference
  expressions, so Def. 1 decisions cannot depend on the backend.

One line per cell; exits non-zero on the first violated invariant.
Usage:  PYTHONPATH=src python tools/substrate_matrix.py
"""
from __future__ import annotations

import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.accounting import sync_bytes_linear  # noqa: E402
from repro.core.learners import LearnerConfig  # noqa: E402
from repro.core.protocol import ProtocolConfig  # noqa: E402
from repro.core.rff import RFFSpec  # noqa: E402
from repro.core.rkhs import KernelSpec  # noqa: E402
from repro.core.substrate import (LinearSubstrate, RFFSubstrate,  # noqa: E402
                                  SVSubstrate)
from repro.data import susy_stream  # noqa: E402
from repro.runtime import (AsyncProtocolConfig, SystemConfig,  # noqa: E402
                           run_async_simulation)
from repro.serving.engine import serve_stream  # noqa: E402

T, M, D = 80, 3, 8


def substrates():
    kcfg = LearnerConfig(algo="kernel_sgd", loss="hinge", eta=0.5, lam=0.01,
                         budget=32, kernel=KernelSpec("gaussian", gamma=0.3),
                         dim=D)
    lcfg = LearnerConfig(algo="linear_sgd", loss="hinge", eta=0.2, lam=0.01,
                         dim=D)
    return [
        ("sv", SVSubstrate(lcfg=kcfg), None),
        ("rff", RFFSubstrate(spec=RFFSpec(dim=D, num_features=64, gamma=0.3,
                                          seed=0)), 64 + 1),
        ("linear", LinearSubstrate(lcfg=lcfg), D + 1),
    ]


def kinds():
    return [
        ("periodic", ProtocolConfig(kind="periodic", period=10),
         AsyncProtocolConfig(kind="periodic", period=10)),
        ("dynamic", ProtocolConfig(kind="dynamic", delta=1.0),
         AsyncProtocolConfig(kind="dynamic", delta=1.0)),
    ]


def _ledger(res):
    return (int(res.num_syncs), int(res.total_bytes),
            float(res.total_loss))


def _run_cell(sub, pcfg, acfg, X, Y):
    """All three drivers for one (substrate, kind, backend) cell."""
    res = engine.run(sub, pcfg, X, Y)
    res_a = run_async_simulation(sub, acfg, X, Y, sys_cfg=SystemConfig(),
                                 record_divergence=False)
    res_s = serve_stream(sub, pcfg, X, Y)
    return res, res_a, res_s


def main() -> int:
    X, Y = susy_stream(T=T, m=M, d=D, seed=0)
    failures = 0
    for sname, sub, num_params in substrates():
        for kname, pcfg, acfg in kinds():
            per_backend = {}
            for backend in ("reference", "pallas"):
                bsub = dataclasses.replace(sub, backend=backend)
                res, res_a, res_s = _run_cell(bsub, pcfg, acfg, X, Y)
                ok = (np.isfinite(res.total_loss)
                      and np.isfinite(res_a.total_loss)
                      and res.num_syncs > 0 and res_a.num_syncs > 0
                      and res.total_bytes > 0
                      # serving replays the same stream: same protocol
                      and res_s.num_syncs == res.num_syncs
                      and res_s.total_bytes == res.total_bytes)
                if num_params is not None:
                    per_sync = sync_bytes_linear(num_params, M)
                    ok = ok and res.total_bytes == res.num_syncs * per_sync
                    ok = (ok and
                          res_a.total_bytes == res_a.num_syncs * per_sync)
                    ok = ok and res.num_syncs == res_a.num_syncs
                per_backend[backend] = tuple(
                    _ledger(r) for r in (res, res_a, res_s))
                print(f"substrate={sname} kind={kname} backend={backend} "
                      f"engine_syncs={res.num_syncs} "
                      f"engine_bytes={res.total_bytes} "
                      f"async_syncs={res_a.num_syncs} "
                      f"async_bytes={res_a.total_bytes} "
                      f"serve_syncs={res_s.num_syncs} "
                      f"serve_bytes={res_s.total_bytes} ok={ok}")
                failures += not ok
            parity = per_backend["reference"] == per_backend["pallas"]
            print(f"substrate={sname} kind={kname} "
                  f"backend_ledger_bitwise_equal={parity}")
            failures += not parity
    print(f"substrate_matrix: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
