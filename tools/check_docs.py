#!/usr/bin/env python3
"""Docs-consistency checker (run in CI).

Docstrings across the tree cite DESIGN.md sections and EXPERIMENTS.md
anchors ("DESIGN.md Sec. 7", "EXPERIMENTS.md §Perf", "EXPERIMENTS.md
Sec. Perf").  This script verifies that every such reference resolves
to an existing heading, and that every *.md file mentioned anywhere in
the tree exists at the repo root — so a doc can never silently go
dangling again (EXPERIMENTS.md was cited for two PRs before it was
written).

It also cross-checks DESIGN.md Sec. 14 against the reprolint rule
registry: every rule id documented there must exist in
``tools.reprolint.rules.ALL_RULES`` and vice versa, so the invariant
catalog and the enforcing code cannot drift apart.

Exits non-zero with one line per broken reference.  Stdlib only.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# importable both as `python tools/check_docs.py` and `-m tools.check_docs`
sys.path.insert(0, str(ROOT))

# Sec. 14 documents each rule as a "**DET01 — title**" subsection.
RULE_DOC_RE = re.compile(r"\*\*([A-Z]{3}\d{2}) —")
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "tools")
# Durable root docs also scanned for cross-references of their own.
ROOT_MD_FILES = ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
                 "CHANGES.md", "PAPER.md", "PAPERS.md", "SNIPPETS.md")

DESIGN_SEC_RE = re.compile(r"DESIGN\.md\s+(?:Secs?\.?\s*)?(\d+)")
# Ranged citations ("DESIGN.md Secs. 6-9", en dash or hyphen) name every
# section in the inclusive span; both endpoints and everything between
# must resolve, or a renumbering could silently orphan the middle.
DESIGN_SEC_RANGE_RE = re.compile(
    r"DESIGN\.md\s+Secs?\.?\s*(\d+)\s*[–—-]\s*(\d+)")
EXPERIMENTS_ANCHOR_RE = re.compile(r"EXPERIMENTS\.md\s+(?:§|Sec\.\s*)(\w+)")
MD_MENTION_RE = re.compile(r"\b([A-Z][A-Z_]+\.md)\b")
# Repo paths named in the durable root docs (README map rows, DESIGN
# module headings, ...) must exist: a rename that forgets the docs
# should fail CI, not linger as a stale pointer.  Matches .py files
# and directories under the scanned trees.
PATH_MENTION_RE = re.compile(
    r"\b((?:src|tools|benchmarks|tests|examples)/[\w./-]*(?:\.py|/))")


def scan_files():
    for d in SCAN_DIRS:
        yield from (ROOT / d).rglob("*.py")
    for name in ROOT_MD_FILES:
        p = ROOT / name
        if p.exists():
            yield p


def main() -> int:
    design = (ROOT / "DESIGN.md").read_text(encoding="utf-8")
    experiments = (ROOT / "EXPERIMENTS.md").read_text(encoding="utf-8")
    design_secs = set(re.findall(r"^##\s+Sec\.\s+(\d+)", design, re.M))
    exp_headings = [l for l in experiments.splitlines()
                    if l.startswith("#")]

    errors = []
    n_refs = 0
    for path in scan_files():
        text = path.read_text(encoding="utf-8")
        rel = path.relative_to(ROOT)
        for m in DESIGN_SEC_RE.finditer(text):
            n_refs += 1
            if m.group(1) not in design_secs:
                errors.append(
                    f"{rel}: DESIGN.md Sec. {m.group(1)} has no heading")
        for m in DESIGN_SEC_RANGE_RE.finditer(text):
            lo, hi = int(m.group(1)), int(m.group(2))
            # the low endpoint is already checked by DESIGN_SEC_RE
            # (which matches the "DESIGN.md Secs. <lo>" prefix of every
            # range), so only the rest of the span is news here
            for sec in range(lo + 1, hi + 1):
                n_refs += 1
                if str(sec) not in design_secs:
                    errors.append(
                        f"{rel}: DESIGN.md Secs. {lo}-{hi} spans Sec. "
                        f"{sec}, which has no heading")
        for m in EXPERIMENTS_ANCHOR_RE.finditer(text):
            n_refs += 1
            tag = m.group(1)
            if not any(f"§{tag}" in h for h in exp_headings):
                errors.append(
                    f"{rel}: EXPERIMENTS.md §{tag} has no heading")
        for m in MD_MENTION_RE.finditer(text):
            name = m.group(1)
            if name == "ISSUE.md":
                continue    # per-PR task file, not a durable doc
            n_refs += 1
            if not (ROOT / name).exists():
                errors.append(f"{rel}: {name} does not exist")
        # CHANGES.md is a historical log: entries may name files that
        # later PRs legitimately removed, so only the living docs are
        # held to path existence.
        if path.suffix == ".md" and path.name != "CHANGES.md":
            for m in PATH_MENTION_RE.finditer(text):
                n_refs += 1
                if not (ROOT / m.group(1)).exists():
                    errors.append(f"{rel}: path {m.group(1)} does not exist")

    # reprolint rule registry <-> DESIGN.md Sec. 14, both directions
    from tools.reprolint.rules import RULE_IDS
    sec14 = design.split("## Sec. 14", 1)
    documented = set(RULE_DOC_RE.findall(sec14[1])) if len(sec14) == 2 else set()
    registered = set(RULE_IDS)
    n_refs += len(documented | registered)
    for rid in sorted(registered - documented):
        errors.append(f"DESIGN.md: reprolint rule {rid} is registered "
                      "but not documented in Sec. 14")
    for rid in sorted(documented - registered):
        errors.append(f"DESIGN.md: Sec. 14 documents rule {rid}, which "
                      "is not in tools.reprolint.rules.ALL_RULES")

    for line in errors:
        print(f"DANGLING: {line}", file=sys.stderr)
    print(f"check_docs: {n_refs} references checked, "
          f"{len(errors)} dangling")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
