"""Repo tooling package.

Making ``tools`` a package lets ``python -m tools.reprolint`` (and
imports like ``from tools.reprolint import scan_source`` in tests and
``tools/check_docs.py``'s registry cross-check) resolve without
sys.path games.  The scripts that are also runnable directly
(``check_docs.py``, ``bench_compare.py``, ``substrate_matrix.py``)
keep working as ``python tools/<script>.py``.
"""
